"""The comm substrate contract (`repro.comm` + the wired engine paths).

What is pinned here:

- **mass conservation** (hypothesis): the error-feedback pack satisfies
  ``wire + residual == delta`` *exactly* in the f32 path (disjoint
  supports — no coordinate is ever rounded), and a whole ship/accumulate
  stream telescopes: shipped + in-flight == produced;
- **Pallas kernel parity**: `kernels.delta_pack` under ``interpret=True``
  matches the jnp reference bit for bit across quants and shapes;
- **widened staleness contract** (hypothesis): under k-clock aggregation
  every channel obeys ``s`` intra-pod and ``s + s_xpod + agg_clocks - 1``
  cross-pod, replica divergence obeys the widened bound, and cross-pod
  visibility only ever lands on shipment boundaries;
- **bit-identity pins**: the default path (``agg_clocks=1, topk_frac=1.0,
  quant="f32"``, substrate off) is bit-identical between engines, and the
  *neutral* substrate (same knobs, ``wire=True``) reproduces the dense
  decisions exactly with views equal to float association;
- **runtime == oracle on the compressed path**: `PSRuntime`/`PodsRuntime`
  with compressed configs match ``core.ps.simulate`` bit for bit
  (thresholds from gathered full rows — the reduction-order discipline of
  the Trace-producer contract extends to the wire);
- **bytes accounting**: ``Trace.ship_floats`` and
  `pods.reconcile.reconcile_stats` measure real compression (dense-eager
  ratio 1.0; aggregated+sparse+quantized > 4x), and the `TimeModel`
  cross-pod tier charges them as seconds over ``bandwidth_xpod``;
- **value-bound analogue** (ROADMAP follow-up (b)):
  `pods.reconcile.replica_value_divergence` holds under VAP (``2 v_t``),
  reports measured-only for async, and rides `cross_validate_pods`.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.comm import substrate as comm
from repro.core import essp, simulate, ssp, vap
from repro.core.consistency import ConsistencyConfig, compressed, podded
from repro.core.ps import PSApp
from repro.core.sweep import stack_configs, sweep
from repro.core.timemodel import TimeModel
from repro.kernels import ops, ref
from repro.pods.reconcile import (reconcile_stats, replica_divergence,
                                  replica_value_divergence)
from repro.psrun import PSRuntime
from repro.psrun.runtime import default_mesh as flat_mesh_for
from repro.psrun.runtime import trace_count
from repro.psrun.validate import TRACE_FIELDS, check_staleness_bound


def make_quad(P, d=16):
    def worker_update(view, local, _wid, clock, rng):
        g = view + 0.05 * jax.random.normal(rng, view.shape)
        return -(0.3 / jnp.sqrt(1.0 + clock)) * g / P, local

    return PSApp(name=f"quad{P}", dim=d, n_workers=P,
                 x0=jnp.ones((d,)) * 2.0,
                 local0={"_": jnp.zeros((P, 1))},
                 worker_update=worker_update,
                 loss=lambda x, l: jnp.sum(jnp.square(x)))


@pytest.fixture(scope="module")
def quad8():
    return make_quad(8)


def oracle(app, cfg, T, seed):
    return jax.jit(lambda sd: simulate(app, cfg, T, seed=sd))(
        jnp.uint32(seed))


def assert_bit_identical(got, want, context=""):
    for name in TRACE_FIELDS:
        np.testing.assert_array_equal(
            np.asarray(getattr(got, name)), np.asarray(getattr(want, name)),
            err_msg=f"{context}:{name}")


POD = dict(s_xpod=3, t_net_xpod=6.0)


# ---------------------------------------------------------------------------
# pack: mass conservation + kernel parity
# ---------------------------------------------------------------------------
@settings(max_examples=15, deadline=None)
@given(seed=st.integers(min_value=0, max_value=10**6),
       topk_frac=st.floats(min_value=0.05, max_value=1.0),
       P=st.sampled_from([1, 4, 8]))
def test_pack_f32_conserves_mass_exactly(seed, topk_frac, P):
    """f32 path: wire and residual have disjoint supports, so
    ``wire + residual == delta`` with zero rounding — shipped plus
    held-back mass is exactly what was accumulated."""
    d = 96
    delta = np.asarray(jax.random.normal(
        jax.random.PRNGKey(seed), (P, d)) * 3.0, np.float32)
    wire, resid, nnz = comm.pack(jnp.asarray(delta), topk_frac, "f32")
    wire, resid = np.asarray(wire), np.asarray(resid)
    assert ((wire == 0) | (resid == 0)).all()          # disjoint supports
    np.testing.assert_array_equal(wire + resid, delta)  # exact, not allclose
    k = int(np.ceil(topk_frac * d))
    assert (np.asarray(nnz) >= k).all()                # ties only ever add


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(min_value=0, max_value=10**6),
       quant=st.sampled_from(["bf16", "int8"]),
       topk_frac=st.floats(min_value=0.1, max_value=1.0))
def test_pack_quantized_residual_carries_error(seed, quant, topk_frac):
    """Quantized paths conserve mass by construction: the residual is
    computed as ``delta - dequant``, so the quantization error re-ships
    later.  ``wire + residual`` matches ``delta`` to one rounding."""
    delta = np.asarray(jax.random.normal(
        jax.random.PRNGKey(seed), (4, 64)) * 2.0, np.float32)
    wire, resid, _ = comm.pack(jnp.asarray(delta), topk_frac, quant)
    np.testing.assert_allclose(np.asarray(wire) + np.asarray(resid), delta,
                               rtol=0, atol=1e-5)
    if quant == "int8":      # wire values live on the 255-level lattice
        scale = np.maximum(np.abs(delta).max(axis=1), 1e-12)[:, None] / 127.0
        q = np.asarray(wire) / scale
        np.testing.assert_allclose(q, np.round(q), atol=1e-4)


def test_stream_conserves_mass():
    """A whole accumulate/ship stream telescopes (f32, any agg/topk):
    everything shipped plus everything still in flight equals everything
    produced — dropped coordinates are delayed, never lost."""
    rng = np.random.default_rng(0)
    P, d, agg, topk = 4, 32, 3, 0.25
    acc = np.zeros((P, d), np.float32)
    res = np.zeros((P, d), np.float32)
    shipped = np.zeros((P, d), np.float64)
    total = np.zeros((P, d), np.float64)
    for t in range(30):
        u = rng.standard_normal((P, d)).astype(np.float32)
        total += u
        acc += u
        if (t + 1) % agg == 0:
            delta = acc + res
            wire, resid, _ = comm.pack(jnp.asarray(delta), topk, "f32")
            np.testing.assert_array_equal(
                np.asarray(wire) + np.asarray(resid), delta)
            shipped += np.asarray(wire)
            res, acc = np.asarray(resid), np.zeros_like(acc)
    np.testing.assert_allclose(shipped + acc + res, total, atol=1e-4)


@pytest.mark.parametrize("quant", ["f32", "bf16", "int8"])
@pytest.mark.parametrize("shape", [(4, 128), (8, 256), (1, 128)])
def test_delta_pack_pallas_interpret_matches_ref(quant, shape):
    P, d = shape
    delta = jax.random.normal(jax.random.PRNGKey(7), (P, d)) * 2.0
    thresh = comm.row_threshold(delta, 0.3)
    scale = comm.quant_scale(delta, quant)
    want = ref.delta_pack(delta, thresh, scale, quant)
    ops.set_backend("pallas_interpret")
    try:
        got = ops.delta_pack(delta, thresh, scale, quant)
    finally:
        ops.set_backend("auto")
    delta_np = np.asarray(delta, np.float32)
    sel = np.abs(delta_np) >= np.asarray(thresh)[:, None]
    for g, w, kind in zip(got, want, ("wire", "res"), strict=True):
        g, w = np.asarray(g), np.asarray(w)
        if quant == "int8":
            # interpret-mode XLA contracts round(x/s)*s differently (FMA):
            # values drift a few ulp and a |x/s| ~ .5 coordinate can round
            # across the lattice step.  The *selection* stays exact (it
            # only reads |delta| vs thresh) and values stay within one
            # lattice step — semantic parity, like the VAP ulp budget.
            step = np.broadcast_to(np.asarray(scale)[:, None] * 1.001,
                                   g.shape)
            np.testing.assert_array_less(np.abs(g - w), step,
                                         err_msg=f"{quant}@{shape}:{kind}")
            ref_zero = (~sel) if kind == "wire" else None
            if ref_zero is not None:
                assert not g[ref_zero].any()     # unselected never ships
        else:
            np.testing.assert_array_equal(g, w, err_msg=f"{quant}@{shape}")


def test_topk_one_is_identity():
    delta = jax.random.normal(jax.random.PRNGKey(1), (4, 64))
    wire, resid, nnz = comm.pack(delta, 1.0, "f32")
    np.testing.assert_array_equal(np.asarray(wire), np.asarray(delta))
    assert not np.asarray(resid).any()
    assert (np.asarray(nnz) == 64).all()
    # dense shipments need no index side-channel
    assert (np.asarray(comm.wire_floats(nnz, 64, "f32")) == 64).all()
    # sparse ones pay 32-bit indices on top of the (quantized) values
    assert float(comm.wire_floats(jnp.asarray([16.0]), 64, "int8")[0]) \
        == 16 * 0.25 + 16


def test_ship_schedule():
    for agg in (1, 2, 3, 5):
        a = jnp.int32(agg)
        for c in range(12):
            end = int(comm.shipped_end(jnp.int32(c), a))
            thr = int(comm.shipped_through(jnp.int32(c), a))
            assert end == ((c + 1) // agg) * agg - 1
            assert thr == (c // agg) * agg - 1
            assert c - agg <= thr <= c - 1      # refresh target stays fresh
            assert thr <= end <= c
            if agg == 1:
                assert (end, thr) == (c, c - 1)  # collapses to dense


# ---------------------------------------------------------------------------
# widened staleness contract + boundary-only cross-pod visibility
# ---------------------------------------------------------------------------
@settings(max_examples=10, deadline=None)
@given(s=st.integers(min_value=0, max_value=3),
       s_xpod=st.integers(min_value=0, max_value=4),
       agg=st.integers(min_value=1, max_value=4),
       topk=st.floats(min_value=0.1, max_value=1.0),
       model=st.sampled_from(["ssp", "essp"]),
       seed=st.integers(min_value=0, max_value=99))
def test_widened_staleness_contract_property(quad8, s, s_xpod, agg, topk,
                                             model, seed):
    """For any knob draw under the substrate: per-channel lag <= s intra /
    s + s_xpod + agg - 1 cross-pod, replica divergence within the widened
    bound, and cross-pod cview only ever sits on shipment boundaries."""
    mk = ssp if model == "ssp" else essp
    cfg = compressed(podded(mk(s, window=14), 2, s_xpod=s_xpod,
                            t_net_xpod=6.0),
                     agg_clocks=agg, topk_frac=topk).replace(window=14)
    tr = jax.jit(lambda sd, c: simulate(quad8, c, 16, seed=sd))(
        jnp.uint32(seed), cfg)
    chk = check_staleness_bound(tr, cfg)     # widened bound, per channel
    assert chk["violations"] == 0, (model, s, s_xpod, agg, chk)
    div = replica_divergence(tr, cfg)
    assert div["bound"] == s + s_xpod + agg - 1
    assert div["ok"], div
    # cross-pod visibility lands only on shipment boundaries
    st_ = np.asarray(tr.staleness)           # [T, P, P], = cview - c
    from repro.core.delays import same_pod_mask
    same = np.asarray(same_pod_mask(8, 2))
    T = st_.shape[0]
    cview = st_ + np.arange(T)[:, None, None]
    xv = cview[:, ~same]
    assert (((xv + 1) % agg == 0) | (xv == -1)).all()


def test_shipments_only_on_boundaries(quad8):
    cfg = compressed(podded(essp(2), 2, **POD), agg_clocks=3, topk_frac=0.5)
    tr = oracle(quad8, cfg, 18, 0)
    ship = np.asarray(tr.ship_floats)        # [T, P]
    clocks = np.arange(ship.shape[0])
    assert (ship[(clocks + 1) % 3 != 0] == 0).all()
    assert (ship[(clocks + 1) % 3 == 0] > 0).all()


# ---------------------------------------------------------------------------
# bit-identity pins (defaults + neutral substrate + runtime == oracle)
# ---------------------------------------------------------------------------
def test_default_path_has_substrate_off():
    assert not ConsistencyConfig().comm_active
    assert not podded(essp(2), 2, s_xpod=3).comm_active
    assert compressed(podded(essp(2), 2), 2, 0.5, "int8").comm_active
    # traced/batched knobs without an explicit wire flag stay OFF ...
    stacked = stack_configs([podded(essp(2), 2, **POD),
                             podded(essp(3), 2, **POD)])
    assert stacked.wire is False
    assert not stacked.comm_active
    # ... and a stacked compressed family stays ON
    stacked_c = stack_configs([
        compressed(podded(essp(2), 2, **POD), 2, 0.5),
        compressed(podded(essp(3), 2, **POD), 4, 0.25)])
    assert stacked_c.wire is True
    assert stacked_c.comm_active


def test_neutral_substrate_matches_dense_decisions(quad8):
    """agg=1 / topk=1.0 / f32 through the substrate ships the exact dense
    delta: every integer decision matches the dense path bit for bit, and
    the float fields agree to association (split-ring summation order)."""
    dense = podded(essp(2), 2, **POD)
    tr_d = oracle(quad8, dense, 25, 3)
    tr_n = oracle(quad8, compressed(dense), 25, 3)
    for f in ("staleness", "forced", "delivered", "ship_floats"):
        np.testing.assert_array_equal(np.asarray(getattr(tr_d, f)),
                                      np.asarray(getattr(tr_n, f)), f)
    np.testing.assert_allclose(np.asarray(tr_d.x_final),
                               np.asarray(tr_n.x_final), rtol=0, atol=1e-5)


def test_dense_ship_floats_schema(quad8):
    """Dense-path ship_floats: d per producer-clock for push models, 0 for
    pull-based ssp — the PR 4 accounting, now recorded in the trace."""
    tr = oracle(quad8, podded(essp(2), 2, **POD), 10, 0)
    assert (np.asarray(tr.ship_floats) == quad8.dim).all()
    tr = oracle(quad8, podded(ssp(2), 2, **POD), 10, 0)
    assert not np.asarray(tr.ship_floats).any()


@pytest.mark.parametrize("cfg", [
    compressed(podded(essp(2), 2, **POD)),
    compressed(podded(essp(2), 2, **POD), 2, 0.25, "int8"),
    compressed(podded(ssp(2), 2, **POD), 3, 0.5, "bf16"),
    compressed(podded(ConsistencyConfig(model="async", staleness=2), 2,
                      **POD), 2, 0.5),
], ids=["neutral", "essp-agg2-int8", "ssp-agg3-bf16", "async-agg2"])
def test_runtime_bit_identical_on_compressed_path(quad8, cfg):
    """The oracle contract extends to the wire: PSRuntime with a
    compressed config reproduces the simulator bit for bit (thresholds
    from gathered full rows, elementwise pack on shards)."""
    rt = PSRuntime(flat_mesh_for(8))
    got = rt.run(quad8, cfg, 20, seed=1)
    assert_bit_identical(got, oracle(quad8, cfg, 20, 1),
                         context=f"comm {cfg.model}/{cfg.quant}")


def test_wired_checkpoint_resume_bit_identical(quad8):
    """`PSState.comm` (acc/res/xring/base_pod/xbase_pod) rides the same
    checkpoint contract as the rest of the state: a mid-run save/restore
    through disk resumes the compressed run bit for bit."""
    import os
    import tempfile

    from repro.checkpoint import io as ckpt
    cfg = compressed(podded(essp(2), 2, **POD), 2, 0.25, "int8")
    rt = PSRuntime(flat_mesh_for(8))
    full, _ = rt.run_fn(quad8, cfg, 20).run_from(
        rt.init_state(quad8, cfg, seed=3), cfg)
    tr1, mid = rt.run_from(quad8, cfg, 8, rt.init_state(quad8, cfg, seed=3))
    with tempfile.TemporaryDirectory() as td:
        path = os.path.join(td, "state.npz")
        ckpt.save_runtime(path, mid)
        restored = ckpt.restore_runtime(
            path, rt.init_state(quad8, cfg, seed=0))
    tr2, _ = rt.run_from(quad8, cfg, 12, restored)
    for name in TRACE_FIELDS:
        if name == "x_final":
            continue
        a = np.concatenate([np.asarray(getattr(tr1, name)),
                            np.asarray(getattr(tr2, name))])
        np.testing.assert_array_equal(
            a, np.asarray(getattr(full, name)), err_msg=name)
    np.testing.assert_array_equal(np.asarray(tr2.x_final),
                                  np.asarray(full.x_final))


def test_comm_knob_changes_reuse_compile(quad8):
    base = compressed(podded(essp(2), 2, **POD), 2, 0.5).replace(window=10)
    rt = PSRuntime(flat_mesh_for(8))
    fn = rt.run_fn(quad8, base, 8)
    fn(0, base)                                  # warm
    n0 = trace_count()
    for cfg in (base.replace(agg_clocks=3, topk_frac=0.25),
                base.replace(agg_clocks=1, topk_frac=1.0),
                base.replace(topk_frac=0.1, s_xpod=1)):
        tr = fn(0, cfg)
        assert np.isfinite(np.asarray(tr.loss_ref)).all()
    assert trace_count() == n0                   # knob moves: no retrace
    # quant is static: a different wire format is a different family
    assert base.family != base.replace(quant="int8").family
    with pytest.raises(ValueError, match="comm_active"):
        fn(0, podded(essp(2), 2, **POD).replace(window=10))  # substrate off


def test_comm_sweep_one_compile_matches_oracle(quad8):
    """agg_clocks/topk_frac batch through the sweep engine like any other
    knob: one compile for the grid, each lane bit-identical to standalone
    simulate."""
    configs = [compressed(podded(essp(2), 2, **POD), a, t)
               for a, t in [(1, 1.0), (2, 0.5), (4, 0.25)]]
    res = sweep(quad8, configs, 12, seeds=1)
    assert res.n_compiles == 1
    for i in range(len(configs)):
        want = jax.jit(lambda c=res.harmonized[i]:
                       simulate(quad8, c, 12, seed=0))()
        assert_bit_identical(res.trace(i, 0), want, context=f"sweep[{i}]")


# ---------------------------------------------------------------------------
# config surface
# ---------------------------------------------------------------------------
def test_config_guards():
    with pytest.raises(ValueError, match="does not apply"):
        ConsistencyConfig(model="bsp", n_pods=2, wire=True)    # barrier
    with pytest.raises(ValueError, match="does not apply"):
        ConsistencyConfig(model="vap", v0=0.5, n_pods=2, wire=True)
    with pytest.raises(ValueError, match="requires n_pods"):
        ConsistencyConfig(model="essp", n_pods=1, wire=True)   # no x-wire
    with pytest.raises(ValueError, match="unknown quant"):
        ConsistencyConfig(model="essp", n_pods=2, quant="fp4")
    with pytest.raises(ValueError, match="agg_clocks"):
        ConsistencyConfig(model="essp", n_pods=2, wire=True, agg_clocks=0)
    with pytest.raises(ValueError, match="topk_frac"):
        ConsistencyConfig(model="essp", n_pods=2, wire=True, topk_frac=0.0)


def test_effective_window_covers_aggregation():
    base = podded(essp(2), 2, s_xpod=3)
    assert base.effective_window == 7
    assert compressed(base, 1).effective_window == 7
    assert compressed(base, 4).effective_window == 10    # + agg - 1
    assert compressed(base).family != base.family        # substrate split


# ---------------------------------------------------------------------------
# bytes accounting: reconcile_stats + TimeModel tier
# ---------------------------------------------------------------------------
def test_reconcile_stats_wire_accounting(quad8):
    dense = podded(essp(1), 2, **POD)
    comp = compressed(dense, agg_clocks=2, topk_frac=0.125, quant="int8")
    T = 40
    rec_d = reconcile_stats(oracle(quad8, dense, T, 0), dense, dim=quad8.dim)
    rec_c = reconcile_stats(oracle(quad8, comp, T, 0), comp, dim=quad8.dim)
    # dense-eager: the true accounting equals the dense counterfactual
    assert rec_d["wire_compression"] == pytest.approx(1.0)
    assert rec_d["dense_equiv_compression"] is not None  # PR 4 ratio kept
    # compressed: agg=2 halves shipments, topk+int8 shrink each one
    assert rec_c["wire_floats"] < rec_d["wire_floats"]
    assert rec_c["wire_compression"] > 4.0
    # gated dense pulls: one d-float delta per pull event
    g = podded(ssp(1), 2, **POD)
    rec_g = reconcile_stats(oracle(quad8, g, T, 0), g, dim=quad8.dim)
    assert rec_g["wire_floats"] == rec_g["gated_pulls"] * quad8.dim


def test_timemodel_xpod_tier(quad8):
    cfg_d = podded(essp(1), 2, **POD)
    cfg_c = compressed(cfg_d, agg_clocks=2, topk_frac=0.125, quant="int8")
    tm = TimeModel(t_comp=0.01, bandwidth_xpod=float(quad8.dim * 4 * 8))
    tr_d, tr_c = oracle(quad8, cfg_d, 20, 0), oracle(quad8, cfg_c, 20, 0)
    wall_d = float(tm.wall_time(tr_d, "essp", cfg=cfg_d)[-1])
    wall_c = float(tm.wall_time(tr_c, "essp", cfg=cfg_c)[-1])
    assert wall_c < wall_d            # fewer bytes -> cheaper clocks
    # dense-eager on this thin pipe is bandwidth-bound: wire time floor
    wire_d = 4.0 * 1 * quad8.dim * quad8.n_workers / tm.bandwidth_xpod
    assert float(tm.per_clock(tr_d, "essp", cfg=cfg_d)[0].min()) \
        >= wire_d - 1e-9
    # without cfg the accounting is the historical single-tier model
    flat = essp(1)
    tr_f = oracle(quad8, flat, 10, 0)
    np.testing.assert_array_equal(
        np.asarray(tm.wall_time(tr_f, "essp")),
        np.asarray(tm.wall_time(tr_f, "essp", cfg=flat)))


# ---------------------------------------------------------------------------
# value-bound analogue for async/VAP replica divergence (follow-up (b))
# ---------------------------------------------------------------------------
def test_replica_value_divergence_vap_checked(quad8):
    cfg = podded(vap(0.5, staleness=3), 2, t_net_xpod=6.0)
    tr = oracle(quad8, cfg, 25, 1)
    out = replica_value_divergence(tr, cfg)
    assert out["ok"] is True
    assert out["violations"] == 0
    assert out["bound_final"] == pytest.approx(2 * 0.5 / np.sqrt(25))
    # clock bound stays None for the unbounded models
    assert replica_divergence(tr, cfg)["bound"] is None
    # negative control: an inflated envelope must be caught
    bad = dataclasses.replace(tr, intransit_inf=tr.intransit_inf + 10.0)
    assert replica_value_divergence(bad, cfg)["ok"] is False


def test_replica_value_divergence_async_measured_only(quad8):
    cfg = podded(ConsistencyConfig(model="async", staleness=2), 2, **POD)
    tr = oracle(quad8, cfg, 20, 0)
    out = replica_value_divergence(tr, cfg)
    assert out["ok"] is None
    assert out["bound_final"] is None
    assert np.isfinite(out["max_envelope"])


def test_cross_validate_pods_reports_value_bound():
    """`cross_validate_pods` wires the value-bound analogue in for the
    unbounded-clock models (and the new wire accounting for all)."""
    from repro.pods import PodsRuntime, cross_validate_pods, \
        default_pods_mesh
    n = len(jax.devices())
    if n < 4 or n % 2:
        pytest.skip("needs a >=4, even device count for a 2-pod mesh")
    rt = PodsRuntime(default_pods_mesh(8, n_pods=2))
    out = cross_validate_pods(
        make_quad(8), podded(vap(0.5, staleness=3), 2, t_net_xpod=6.0),
        15, runtime=rt)
    assert out["ok"], out
    assert out["replica_value_divergence"]["violations"] == 0
    assert "wire_floats" in out["reconcile"]
