"""Traced TimeModel + sweep-driven auto-tuner (`core.timemodel`,
`core.tune`, the sweep `post` path) and the straggler-bias regression."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import essp, simulate, ssp, sweep, tune, vap
from repro.core.ps import Trace
from repro.core import staleness
from repro.core.sweep import trace_count
from repro.core.timemodel import TimeModel
from repro.apps.matfact import MFConfig, make_mf_app


# ---------------- lognormal straggler bias (regression) --------------------
def test_straggler_draws_mean_is_t_comp():
    """mu = -sigma^2/2 makes t_comp the *true mean* compute time.  The old
    numpy path drew lognormal(0, sigma) whose mean is exp(sigma^2/2) x
    t_comp (~4.6% high at sigma=0.3, ~13% at sigma=0.5)."""
    for sigma in (0.3, 0.5):
        tm = TimeModel(t_comp=0.05, straggler_sigma=sigma)
        draws = np.asarray(tm.comp_draws((400_000,)))
        assert abs(draws.mean() / tm.t_comp - 1.0) < 0.01, sigma
        # and the draws are genuinely heavy-tailed, not degenerate
        assert draws.std() > 0.2 * tm.t_comp


def test_per_clock_mean_comp_tracks_t_comp(quad_app):
    tm = TimeModel()
    tr = jax.jit(lambda: simulate(quad_app, essp(3), 200))()
    _, comp, _ = tm.per_clock_np(tr, "essp")
    # per-clock comp is the *max* over P workers, so it sits above t_comp;
    # the underlying draws average to t_comp
    draws = np.asarray(tm.comp_draws((200, quad_app.n_workers)))
    assert abs(draws.mean() / tm.t_comp - 1.0) < 0.02


# ---------------- traced vs numpy equivalence ------------------------------
def _np_reference_per_clock(tm, comp, forced, model):
    """Independent numpy reimplementation of the wall-clock accounting
    (given the compute draws) — deliberately duplicated here so the traced
    path is checked against something other than itself."""
    comp = np.asarray(comp, np.float64)
    forced = np.asarray(forced).astype(np.float64)
    T, P, _ = forced.shape
    xfer = tm.bytes_per_channel / tm.bandwidth
    sync = forced.sum(axis=2) * (tm.rtt + xfer)
    if model == "bsp":
        comp_clock = comp.max(axis=1)
        comm_clock = np.full(T, tm.barrier_overhead + (P - 1) * xfer + tm.rtt)
    else:
        worst = (comp + sync).argmax(axis=1)
        comp_clock = comp[np.arange(T), worst]
        comm_clock = sync[np.arange(T), worst]
    return comp_clock + comm_clock, comp_clock, comm_clock


def test_traced_matches_numpy_reference(quad_app):
    tm = TimeModel()
    tr = jax.jit(lambda: simulate(quad_app, ssp(4), 40))()
    comp = tm.comp_draws((40, quad_app.n_workers), fold=(3, 7))
    for model in ("ssp", "bsp"):
        want = _np_reference_per_clock(tm, comp, tr.forced, model)
        got = jax.jit(
            lambda t: tm.per_clock(t, model, fold=(3, 7)))(tr)  # noqa: B023
        for a, b in zip(got, want, strict=True):
            np.testing.assert_allclose(np.asarray(a), b, rtol=1e-5)
        # the numpy-facing shims agree with the traced path
        np.testing.assert_allclose(
            np.asarray(jax.jit(lambda t: tm.wall_time(t, model))(tr)),  # noqa: B023
            tm.wall_time_np(tr, model), rtol=1e-6)
        np.testing.assert_allclose(tm.wall_time_np(tr, model, fold=(3, 7)),
                                   np.cumsum(want[0]), rtol=1e-5)
    br = tm.breakdown(tr, "ssp")
    assert br["total_s"] == pytest.approx(br["comp_s"] + br["comm_s"],
                                          rel=1e-6)
    assert 0.0 < br["comm_frac"] < 1.0


def test_timemodel_vmaps_over_batched_traces(quad_app):
    """The traced model consumes a sweep's batched Trace leaves on device."""
    tm = TimeModel()
    res = sweep(quad_app, [essp(2), essp(5)], 30, seeds=2)
    batched = res.traces[0]                      # leaves [n_seeds, ...]
    walls = jax.vmap(lambda t: tm.wall_time(t, "essp"))(batched)
    assert walls.shape == (2, 30)
    want = tm.wall_time_np(res.trace(0, 1), "essp")
    np.testing.assert_allclose(np.asarray(walls[1]), want, rtol=1e-6)


# ---------------- RNG folding ----------------------------------------------
def test_fold_decorrelates_configs_and_seeds(quad_app):
    tm = TimeModel()
    tr = jax.jit(lambda: simulate(quad_app, essp(3), 25))()
    w00 = tm.wall_time_np(tr, "essp", fold=(0, 0))
    w10 = tm.wall_time_np(tr, "essp", fold=(1, 0))
    w01 = tm.wall_time_np(tr, "essp", fold=(0, 1))
    # deterministic: same fold -> identical draws
    np.testing.assert_array_equal(w00, tm.wall_time_np(tr, "essp",
                                                       fold=(0, 0)))
    # different config index / seed -> independent straggler realizations
    assert np.abs(w00 - w10).max() > 0
    assert np.abs(w00 - w01).max() > 0
    assert np.abs(w10 - w01).max() > 0


# ---------------- sweep post path ------------------------------------------
def test_sweep_post_runs_in_single_compile(quad_app):
    tm = TimeModel()
    configs = [essp(s, push_prob=p) for s in (1, 4) for p in (0.5, 0.9)]
    n0 = trace_count()
    res = sweep(quad_app, configs, 20, seeds=3,
                post=tune.metrics_post(tm, tail=5))
    assert res.n_compiles == 1
    assert trace_count() - n0 == 1
    # post outputs are batched per config like traces, and equal the traced
    # TimeModel applied to the standalone trace with the same fold
    for i in (0, 3):
        for j, sd in enumerate(res.seeds):
            want = tm.wall_time_np(res.trace(i, j), "essp",
                                   fold=(i, int(sd)))
            got = np.asarray(res.posts[i]["cum_wall"][j])
            np.testing.assert_allclose(got, want, rtol=1e-6)
            np.testing.assert_allclose(
                float(res.posts[i]["final_loss"][j]),
                float(np.asarray(res.trace(i, j).loss_ref)[-5:].mean()),
                rtol=1e-6)


def test_sweep_keep_traces_false_drops_traces(quad_app):
    tm = TimeModel()
    res = sweep(quad_app, [essp(2), essp(4)], 15, seeds=2,
                post=tune.metrics_post(tm), keep_traces=False)
    assert res.posts[0]["loss"].shape == (2, 15)
    with pytest.raises(ValueError, match="keep_traces"):
        res.trace(0)
    with pytest.raises(ValueError, match="post callback"):
        sweep(quad_app, [essp(2)], 5, keep_traces=False)


# ---------------- tuner frontier -------------------------------------------
@pytest.fixture(scope="module")
def mf_app_small():
    return make_mf_app(MFConfig(n_rows=64, n_cols=64, rank=8, true_rank=8,
                                n_workers=4, batch=64, lr=0.5))


def test_frontier_essp_dominates_ssp(mf_app_small):
    """C2/C6 sanity under the paper's constants: at equal staleness, ESSP
    reaches the common loss threshold in fewer modeled wall seconds than
    lazy SSP (background pushes instead of blocking refreshes)."""
    n0 = trace_count()
    fr = tune.frontier(mf_app_small, [ssp(5), essp(5)],
                       {"push_prob": [0.5, 0.9]},
                       time_model=TimeModel(), n_clocks=120, seeds=2)
    assert trace_count() - n0 == 2          # one compile per family
    tts = {m: min(p["wall_to_threshold"] for p in fr.points
                  if p["config"].model == m) for m in ("ssp", "essp")}
    assert np.isfinite(tts["essp"])
    assert tts["essp"] < tts["ssp"]
    # the frontier contains an essp point and no point dominates another
    assert any(p["config"].model == "essp" for p in fr.frontier)
    xs = [p["final_loss"] for p in fr.frontier]
    ys = [p["wall_to_threshold"] for p in fr.frontier]
    assert xs == sorted(xs)
    assert ys == sorted(ys, reverse=True)


@pytest.mark.slow
def test_frontier_refinement_only_improves(quad_app):
    tm = TimeModel()
    coarse = tune.frontier(quad_app, essp(3), {"push_prob": [0.3, 0.7]},
                           time_model=tm, n_clocks=50, seeds=2,
                           threshold=0.05)
    fine = tune.frontier(quad_app, essp(3), {"push_prob": [0.3, 0.7]},
                         time_model=tm, n_clocks=50, seeds=2,
                         threshold=0.05, refine_rounds=2)
    assert len(fine.points) > len(coarse.points)
    assert (fine.best()["wall_to_threshold"]
            <= coarse.best()["wall_to_threshold"] + 1e-9)
    # refined knobs stay in bounds
    assert all(0.05 <= float(p["config"].push_prob) <= 1.0
               for p in fine.points)


def test_pareto_indices():
    xs = np.array([1.0, 2.0, 3.0, 0.5, 2.5])
    ys = np.array([3.0, 1.0, 2.0, 4.0, np.inf])
    idx = tune.pareto_indices(xs, ys)
    assert idx == [3, 0, 1]                  # sorted by x, all non-dominated


def test_grid_configs_cartesian_product():
    cfgs = tune.grid_configs([ssp(1), essp(1)],
                             {"staleness": [1, 3], "push_prob": [0.5, 0.9]})
    assert len(cfgs) == 8
    assert len({c.family for c in cfgs}) == 2


# ---------------- gradient through the sweep --------------------------------
def test_grad_through_sweep_smoke(quad_app):
    """`jax.grad` of loss-at-fixed-wall-budget w.r.t. traced knobs runs and
    is finite; the continuous time-model path (t_comp shifts how many
    clocks the budget buys) carries non-degenerate gradient."""
    tm = TimeModel()
    out = tune.grad_knobs(quad_app, essp(3), 40, tm, budget=1.0,
                          knobs=("push_prob",), tm_knobs=("t_comp",))
    assert np.isfinite(out["value"])
    assert all(np.isfinite(g) for g in out["grads"].values())
    assert out["grads"]["t_comp"] != 0.0


def test_grad_vap_v0_smoke(quad_app):
    tm = TimeModel()
    out = tune.grad_knobs(quad_app, vap(0.5, staleness=4), 25, tm,
                          budget=0.8, knobs=("v0",), tm_knobs=())
    assert np.isfinite(out["grads"]["v0"])


def test_loss_at_budget_monotone_in_budget(quad_app):
    """More wall budget -> at or past the same clocks -> lower soft loss on
    a converging run."""
    tm = TimeModel()
    f = jax.jit(lambda b: tune.loss_at_budget(quad_app, essp(3), 60, tm, b,
                                              temp=0.5))
    assert float(f(4.0)) < float(f(0.5))


# ---------------- staleness warm-up fix -------------------------------------
def _fake_trace(st):
    z = jnp.zeros(())
    st = jnp.asarray(st)
    return Trace(loss_ref=z, loss_view=z, staleness=st,
                 forced=z, delivered=z, u_l2=z, intransit_inf=z,
                 ship_floats=z, live=jnp.ones(st.shape[:2], bool),
                 views0=None, x_final=z, locals_final=None)


def test_summary_skips_warmup_clocks():
    """Clocks where every off-diagonal cview is still the initial -1 are
    cold-start artifacts, not staleness measurements."""
    P = 2
    # clock 0: cview=-1 (diff -1), clock 1: cview=-1 (diff -2)  -> warm
    # clock 2: cview=1  (diff -1)                               -> real
    st = np.stack([np.full((P, P), -1), np.full((P, P), -2),
                   np.full((P, P), -1)]).astype(np.int32)
    tr = _fake_trace(st)
    s = staleness.summary(tr)
    assert s["mean"] == -1.0
    assert s["min"] == -1
    assert s["max"] == -1
    # unskipped distribution still includes the -2 warm-up reads
    assert staleness.clock_differentials(tr).min() == -2


def test_summary_all_warmup_falls_back():
    st = np.stack([np.full((3, 3), -(c + 1)) for c in range(4)]).astype(
        np.int32)
    s = staleness.summary(_fake_trace(st))
    assert np.isfinite(s["mean"])
    assert s["min"] == -4


def test_histogram_empty_trace_does_not_crash():
    st = np.zeros((0, 3, 3), np.int32)
    bins, probs = staleness.histogram(_fake_trace(st))
    assert probs.sum() == 0.0
    assert len(bins) == len(probs)


def test_warmup_skip_makes_lazy_ssp_less_negative(quad_app):
    tr = jax.jit(lambda: simulate(quad_app, ssp(6), 40))()
    with_skip = staleness.clock_differentials(tr, skip_warmup=True)
    without = staleness.clock_differentials(tr, skip_warmup=False)
    assert with_skip.size < without.size
    assert with_skip.mean() >= without.mean()
