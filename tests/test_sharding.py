"""Sharding-rule logic (mesh-free parts + small fake meshes)."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs import get_config
from repro.launch import sharding as shd
from repro.models.params import spec, shardings


@pytest.fixture(scope="module")
def mesh1():
    # single-device mesh with both axis names (size 1 each)
    dev = np.array(jax.devices()[:1]).reshape(1, 1)
    return Mesh(dev, ("data", "model"))


def test_ns_drops_non_dividing_axes(mesh1):
    s = shd.ns(mesh1, (7, 8), "data", "model")
    # axes of size 1 always divide; spec keeps them
    assert s.spec == P("data", "model")


def test_ns_skips_missing_axes(mesh1):
    s = shd.ns(mesh1, (8, 8), ("pod", "data"), None)
    assert s.spec == P("data", None)   # no "pod" axis on this mesh


def test_ns_no_axis_reuse(mesh1):
    s = shd.ns(mesh1, (8, 8), "model", "model")
    assert s.spec == P("model", None)  # second use dropped


def test_param_rules_profiles():
    tp = shd.param_rules("tp")
    fsdp = shd.param_rules("tp_fsdp")
    assert tp["embed"] is None
    assert fsdp["embed"] == shd.DATA_AXES
    assert tp["heads"] == "model"
    assert tp["experts"] == "model"


def test_profile_selection():
    assert shd.profile_for(get_config("jamba-1.5-large-398b")) == "tp_fsdp"
    assert shd.profile_for(get_config("llama3-8b")) == "tp"
    assert shd.profile_for(get_config("qwen3-0.6b")) == "tp"


def test_activation_rules_sp_toggle():
    from repro.configs.base import INPUT_SHAPES
    train = shd.activation_rules(INPUT_SHAPES["train_4k"])
    dec = shd.activation_rules(INPUT_SHAPES["decode_32k"])
    assert train["seq_res"] == "model"      # sequence parallelism on
    assert dec["seq_res"] is None           # decode: seq=1


def test_param_shardings_tree(mesh1):
    specs = {"w": spec((8, 16), ("embed", "mlp")),
             "e": spec((32, 8), ("vocab", "embed"))}
    tree = shardings(specs, mesh1, shd.param_rules("tp"))
    assert tree["w"].spec == P(None, "model")
    assert tree["e"].spec == P("model", None)


def test_roofline_row_math():
    from benchmarks.roofline import roofline_row
    art = {
        "arch": "llama3-8b", "shape": "train_4k", "mesh": "16x16",
        "chips": 256, "kind": "train",
        "flops_per_device": 197e12,           # exactly 1s of compute
        "bytes_accessed_per_device": 819e9,   # exactly 1s of HBM
        "collectives": {"total_bytes": 150e9, "count_by_op": {}},
        "memory": {"total_bytes": 8 * 2**30},
    }
    r = roofline_row(art)
    assert r["compute_s"] == pytest.approx(1.0)
    assert r["memory_s"] == pytest.approx(1.0)
    assert r["collective_s"] == pytest.approx(1.0)
    assert r["fits_hbm"]
    # llama3-8b train_4k model flops: 6 * ~8.03B * 1.048M tokens ~ 5.05e16
    assert 4.8e16 < r["model_flops"] < 5.4e16


def test_active_params_moe():
    from benchmarks.roofline import active_params
    full = active_params("llama3-8b")
    assert full == pytest.approx(8.03e9, rel=0.05)
    act = active_params("qwen3-moe-30b-a3b")
    total = active_params("qwen3-0.6b")  # sanity: returns floats
    assert 2e9 < act < 4.5e9             # ~3B active of 30B total
    assert act < 0.2 * 30e9
