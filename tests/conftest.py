import os
import sys

# Tests must see a *deliberate* device topology: pop any ambient XLA_FLAGS
# (the dry-run sets its own in a subprocess; nothing may leak in), then
# honor the explicit opt-in used by the CI forced-multi-device lane so the
# shard_map paths (core/sweep, repro/psrun) run genuinely sharded.
os.environ.pop("XLA_FLAGS", None)
_n_dev = os.environ.get("REPRO_FORCE_HOST_DEVICES")
if _n_dev:
    os.environ["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={int(_n_dev)}")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.dirname(__file__))

try:
    import hypothesis  # noqa: F401
except ImportError:
    # Offline containers: fall back to the deterministic stub so the
    # property tests still collect and run.  CI installs the real package
    # (`pip install -e .[test]`).
    import _hypothesis_stub
    _hypothesis_stub.install()

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import pytest  # noqa: E402

from repro.core.ps import PSApp  # noqa: E402


@pytest.fixture(scope="session")
def quad_app():
    """Tiny quadratic PS app: minimize ||x||^2 with noisy worker gradients.

    Fast enough for hypothesis sweeps over consistency configs.
    """
    P, d = 4, 16
    eta = 0.3

    def worker_update(view, local, _wid, clock, rng):
        g = view + 0.05 * jax.random.normal(rng, view.shape)
        step = eta / jnp.sqrt(1.0 + clock)
        return -step * g / P, local

    def loss(x, _locals):
        return jnp.sum(jnp.square(x))

    x0 = jnp.ones((d,)) * 2.0
    return PSApp(name="quad", dim=d, n_workers=P, x0=x0,
                 local0={"_": jnp.zeros((P, 1))},
                 worker_update=worker_update, loss=loss)
