"""The pods oracle contract: the hierarchical multi-pod PS vs the simulator.

Contract being pinned (see ``pods/validate.py`` and the hierarchical-mode
section of ``core/ps.py``):

- the simulator's hierarchical mode *collapses* correctly: ``n_pods=1`` is
  bit-identical to the flat simulator, BSP is bit-identical across any pod
  count, and an equal-tier multi-pod ESSP equals the flat run;
- ``PodsRuntime`` on a ``("pod","data","model")`` mesh matches
  ``core.ps.simulate`` with the same hierarchical config: BSP/SSP/ESSP
  bit-identical, VAP with exact decisions within the strict ulp budget
  (``psrun.validate.VAP_ULP_BUDGET``);
- the two-tier staleness invariant holds for arbitrary knob draws
  (hypothesis): per-channel lag <= ``s_intra + s_xpod``, intra-pod
  channels additionally <= ``s_intra``; replica divergence on the
  reconciliation channel <= ``s_intra + s_xpod``;
- mid-run state checkpoints (``checkpoint.io.save_runtime``) resume
  bit-for-bit, through disk;
- ``core.sweep`` shards a (config x seed) batch over the pod axis of the
  multi-pod mesh bit-identically;
- numeric knob changes (including the new ``s_xpod``/``t_net_*`` tier
  knobs) reuse the compiled program.

Under the CI pods lane (``REPRO_FORCE_HOST_DEVICES=16``) the runtime tests
run genuinely sharded over a 2x4x2 mesh; on fewer devices the helpers fall
back to the widest mesh available (the semantics are placement-independent
— that is the point of the contract).
"""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.checkpoint import io as ckpt
from repro.core import bsp, essp, simulate, ssp, vap
from repro.core.consistency import ConsistencyConfig, podded
from repro.core.delays import pod_of, same_pod_mask, staleness_bound_matrix
from repro.core.ps import PSApp
from repro.core.sweep import sweep
from repro.launch.mesh import make_pods_mesh
from repro.pods import (PodsRuntime, cross_validate_pods, default_pods_mesh,
                        replica_divergence)
from repro.pods.runtime import trace_count
from repro.psrun import PSRuntime
from repro.psrun.runtime import default_mesh as flat_mesh_for
from repro.psrun.validate import (TRACE_FIELDS, VAP_ULP_BUDGET,
                                  check_staleness_bound, trace_max_ulp)


def assert_bit_identical(got, want, context=""):
    for name in TRACE_FIELDS:
        a, b = np.asarray(getattr(got, name)), np.asarray(getattr(want, name))
        np.testing.assert_array_equal(a, b, err_msg=f"{context}:{name}")


def make_quad(P, d=16):
    def worker_update(view, local, _wid, clock, rng):
        g = view + 0.05 * jax.random.normal(rng, view.shape)
        return -(0.3 / jnp.sqrt(1.0 + clock)) * g / P, local

    return PSApp(name=f"quad{P}", dim=d, n_workers=P,
                 x0=jnp.ones((d,)) * 2.0,
                 local0={"_": jnp.zeros((P, 1))},
                 worker_update=worker_update,
                 loss=lambda x, l: jnp.sum(jnp.square(x)))


def pods_runtime_for(n_workers, n_pods):
    """A PodsRuntime on the widest mesh the host supports; on hosts without
    enough devices for a physical pod axis, the flat runtime carries the
    hierarchical config (placement-independent semantics)."""
    n = len(jax.devices())
    if n >= 2 * n_pods and n % n_pods == 0:
        return PodsRuntime(default_pods_mesh(n_workers, n_pods=n_pods))
    return PSRuntime(flat_mesh_for(n_workers))


@pytest.fixture(scope="module")
def quad8():
    return make_quad(8)


@pytest.fixture(scope="module")
def quad8_rt2():
    return pods_runtime_for(8, 2)


@pytest.fixture(scope="module")
def mf16():
    from repro.apps.matfact import MFConfig, make_mf_app
    return make_mf_app(MFConfig(n_rows=64, n_cols=64, rank=8, true_rank=8,
                                n_workers=16, batch=64, lr=0.5))


def oracle(app, cfg, T, seed):
    return jax.jit(lambda sd: simulate(app, cfg, T, seed=sd))(
        jnp.uint32(seed))


HIER = dict(s_xpod=3, t_net_xpod=6.0)


# ---------------------------------------------------------------------------
# simulator hierarchical mode: collapse properties
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("cfg", [bsp(), ssp(3), essp(3),
                                 vap(0.5, staleness=4)],
                         ids=lambda c: c.model)
def test_simulate_pod1_collapses_to_flat(quad8, cfg):
    """`podded(cfg, 1)` is bit-identical to the flat simulator."""
    assert_bit_identical(oracle(quad8, podded(cfg, 1), 20, 0),
                         oracle(quad8, cfg, 20, 0), context=cfg.model)


def test_simulate_bsp_bit_identical_across_pod_counts(quad8):
    """The barrier drains both tiers: BSP traces don't depend on n_pods."""
    want = oracle(quad8, bsp(), 20, 1)
    for n_pods in (2, 4):
        got = oracle(quad8, podded(bsp(), n_pods, s_xpod=5, t_net_xpod=9.0),
                     20, 1)
        assert_bit_identical(got, want, context=f"bsp pods={n_pods}")


def test_simulate_equal_tier_pods_equal_flat(quad8):
    """With t_net_xpod == t_net_intra and s_xpod=0 the pod partition is
    unobservable — the hierarchical run equals the flat one bit for bit."""
    assert_bit_identical(oracle(quad8, podded(essp(3), 2), 25, 2),
                         oracle(quad8, essp(3), 25, 2), context="equal-tier")


def test_simulate_xpod_channels_are_staler(quad8):
    """A slow cross-pod tier shows up as strictly staler cross-pod
    channels, while intra-pod channels keep the tight bound."""
    cfg = podded(essp(2), 2, s_xpod=4, t_net_xpod=8.0)
    tr = oracle(quad8, cfg, 40, 0)
    st = np.asarray(tr.staleness)
    same = np.asarray(same_pod_mask(8, 2))
    assert st[:, same].min() >= -(2 + 1)
    assert st[:, ~same].min() >= -(2 + 4 + 1)
    assert st.max() <= -1
    assert st[:, ~same].mean() < st[:, same].mean()


# ---------------------------------------------------------------------------
# PodsRuntime vs the hierarchical oracle (the acceptance contract)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("cfg", [
    podded(bsp(), 2, **HIER),
    podded(ssp(2), 2, **HIER),
    podded(essp(2), 2, **HIER),
], ids=lambda c: c.model)
def test_pods_runtime_bit_identical_quad(quad8, quad8_rt2, cfg):
    got = quad8_rt2.run(quad8, cfg, 20, seed=1)
    assert_bit_identical(got, oracle(quad8, cfg, 20, 1),
                         context=f"pods {cfg.model}")


@pytest.mark.parametrize("cfg", [
    podded(bsp(), 2, **HIER),
    podded(ssp(2), 2, **HIER),
    podded(essp(2), 2, **HIER),
    podded(vap(0.5, staleness=4), 2, t_net_xpod=6.0),
], ids=lambda c: c.model)
def test_pods_runtime_bit_identical_mf16(mf16, cfg):
    """The acceptance app on the acceptance topology (2x4x2 under the CI
    pods lane): bit-identical for every model — including VAP, whose
    drift allowance the MF float chain does not need."""
    rt = pods_runtime_for(16, 2)
    got = rt.run(mf16, cfg, 10, seed=1)
    want = oracle(mf16, cfg, 10, 1)
    assert_bit_identical(got, want, context=f"mf16 {cfg.model}")


def test_pods_cross_validate_all_models(quad8, quad8_rt2):
    for cfg in (podded(bsp(), 2, **HIER), podded(ssp(1), 2, **HIER),
                podded(essp(1), 2, **HIER),
                podded(vap(0.5, staleness=3), 2, t_net_xpod=6.0)):
        if isinstance(quad8_rt2, PodsRuntime):
            out = cross_validate_pods(quad8, cfg, 20, runtime=quad8_rt2)
        else:  # single-device fallback: flat runtime, same contract
            from repro.psrun.validate import cross_validate
            out = cross_validate(quad8, cfg, 20, runtime=quad8_rt2)
        assert out["ok"], out


def test_pods_vap_decisions_exact_ulp_bounded(quad8, quad8_rt2):
    cfg = podded(vap(0.5, staleness=3), 2, t_net_xpod=6.0)
    got = quad8_rt2.run(quad8, cfg, 20, seed=1)
    want = oracle(quad8, cfg, 20, 1)
    for name in ("staleness", "forced", "delivered"):
        np.testing.assert_array_equal(np.asarray(getattr(got, name)),
                                      np.asarray(getattr(want, name)))
    ulps = trace_max_ulp(got, want)
    assert max(ulps.values()) <= VAP_ULP_BUDGET, ulps


# ---------------------------------------------------------------------------
# two-tier staleness + replica divergence (hypothesis property)
# ---------------------------------------------------------------------------
@settings(max_examples=10, deadline=None)
@given(s=st.integers(min_value=0, max_value=4),
       s_xpod=st.integers(min_value=0, max_value=5),
       push_prob=st.floats(min_value=0.2, max_value=1.0),
       t_net_xpod=st.floats(min_value=1.0, max_value=12.0),
       model=st.sampled_from(["ssp", "essp"]),
       n_pods=st.sampled_from([1, 2, 4]),
       seed=st.integers(min_value=0, max_value=99))
def test_two_tier_staleness_and_divergence_property(
        quad8, s, s_xpod, push_prob, t_net_xpod, model, n_pods, seed):
    """For any knob draw: per-channel lag <= s_eff (s intra, s + s_xpod
    cross-pod), reads never beat the barrier, and the pods' visible
    prefixes of one producer never diverge past s + s_xpod.  The fixed
    ring window keeps all draws inside one compile per (model, n_pods)."""
    mk = ssp if model == "ssp" else essp
    cfg = podded(mk(s, window=12), n_pods, s_xpod=s_xpod,
                 t_net_xpod=t_net_xpod).replace(push_prob=push_prob)
    tr = jax.jit(lambda sd, c: simulate(quad8, c, 15, seed=sd))(
        jnp.uint32(seed), cfg)
    chk = check_staleness_bound(tr, cfg)       # two-tier, per channel
    assert chk["violations"] == 0, (model, n_pods, s, s_xpod, chk)
    assert chk["max"] == -1                    # reads always lag the barrier
    # intra-pod channels keep the *tight* bound regardless of s_xpod
    st_ = np.asarray(tr.staleness)
    same = np.asarray(same_pod_mask(8, n_pods))
    assert st_[:, same].min() >= -(s + 1)
    div = replica_divergence(tr, cfg)
    assert div["ok"], div


def test_replica_divergence_bound_on_runtime(quad8, quad8_rt2):
    cfg = podded(essp(1), 2, s_xpod=4, t_net_xpod=8.0)
    tr = quad8_rt2.run(quad8, cfg, 30, seed=3)
    div = replica_divergence(tr, cfg)
    assert div["bound"] == 5, div
    assert div["ok"], div


# ---------------------------------------------------------------------------
# checkpoint: mid-run state resumes bit-for-bit (through disk)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("cfg", [
    podded(bsp(), 2, **HIER),
    podded(essp(2), 2, **HIER),
    podded(vap(0.5, staleness=3), 2, t_net_xpod=6.0),
], ids=lambda c: c.model)
def test_checkpoint_resume_bit_identical(quad8, quad8_rt2, cfg):
    rt = quad8_rt2
    full, _ = rt.run_fn(quad8, cfg, 20).run_from(
        rt.init_state(quad8, cfg, seed=3), cfg)
    tr1, mid = rt.run_from(quad8, cfg, 8, rt.init_state(quad8, cfg, seed=3))
    with tempfile.TemporaryDirectory() as td:
        path = os.path.join(td, "state.npz")
        ckpt.save_runtime(path, mid)
        restored = ckpt.restore_runtime(
            path, rt.init_state(quad8, cfg, seed=0))
    tr2, _ = rt.run_from(quad8, cfg, 12, restored)
    for name in TRACE_FIELDS:
        if name == "x_final":
            continue
        a = np.concatenate([np.asarray(getattr(tr1, name)),
                            np.asarray(getattr(tr2, name))])
        np.testing.assert_array_equal(
            a, np.asarray(getattr(full, name)), err_msg=name)
    np.testing.assert_array_equal(np.asarray(tr2.x_final),
                                  np.asarray(full.x_final))
    # and the segmented run equals the plain seed entry point
    plain = rt.run(quad8, cfg, 20, seed=3)
    np.testing.assert_array_equal(np.asarray(plain.x_final),
                                  np.asarray(full.x_final))


# ---------------------------------------------------------------------------
# sweep over the pod axis
# ---------------------------------------------------------------------------
def test_sweep_shards_over_pod_axis(quad8):
    """A hierarchical (config x seed) batch sharded over the "pod" axis of
    the multi-pod mesh reproduces standalone `simulate` bit for bit, in
    one compile."""
    mesh = make_pods_mesh()        # widest mesh for this host
    configs = [podded(essp(s), 2, **HIER) for s in (1, 2, 4)]
    res = sweep(quad8, configs, 15, seeds=2, mesh=mesh, mesh_axis="pod")
    assert res.n_compiles == 1
    for i in range(len(configs)):
        for j, sd in enumerate([0, 1]):
            want = jax.jit(
                lambda c=res.harmonized[i], s=sd:
                simulate(quad8, c, 15, seed=s))()
            assert_bit_identical(res.trace(i, j), want,
                                 context=f"pod-sweep[{i}] seed={sd}")


# ---------------------------------------------------------------------------
# compile reuse + API guards
# ---------------------------------------------------------------------------
def test_tier_knob_changes_reuse_compile(quad8, quad8_rt2):
    base = podded(essp(2), 2, s_xpod=3, t_net_xpod=6.0)
    fn = quad8_rt2.run_fn(quad8, base, 10)
    fn(0, base)                                  # warm
    n0 = trace_count()
    W = base.effective_window
    for cfg in (podded(essp(1), 2, s_xpod=2, t_net_xpod=12.0),
                podded(essp(3), 2, s_xpod=1, t_net_intra=2.0),
                podded(essp(2), 2, s_xpod=3).replace(push_prob=0.4)):
        tr = fn(0, cfg.replace(window=W))
        assert np.isfinite(np.asarray(tr.loss_ref)).all()
    assert trace_count() == n0                   # no retrace for knob moves


def test_pods_runtime_rejects_mismatched_n_pods(quad8):
    n = len(jax.devices())
    if n < 4 or n % 2:
        pytest.skip("needs a >=4, even device count for a 2-pod mesh")
    rt = PodsRuntime(default_pods_mesh(8, n_pods=2))
    with pytest.raises(ValueError, match="pod axis"):
        rt.run_fn(quad8, essp(2), 5)             # n_pods=1 config on 2 pods


def test_pod_partition_guards():
    with pytest.raises(ValueError, match="must divide"):
        pod_of(8, 3)                             # 8 workers, 3 pods
    with pytest.raises(ValueError, match="n_pods"):
        ConsistencyConfig(model="essp", n_pods=0)
    with pytest.raises(ValueError, match="s_xpod"):
        ConsistencyConfig(model="essp", s_xpod=-1)


def test_staleness_bound_matrix_tiers():
    cfg = podded(essp(2), 2, s_xpod=3)
    m = np.asarray(staleness_bound_matrix(cfg, jnp.arange(8), 8))
    same = np.asarray(same_pod_mask(8, 2))
    assert (m[same] == 2).all()
    assert (m[~same] == 5).all()


def test_effective_window_covers_xpod():
    assert podded(essp(2), 2, s_xpod=3).effective_window == 7
    assert podded(ssp(1), 4, s_xpod=0).effective_window == 3
    # family splits on n_pods (a different channel-tier mask)
    assert podded(essp(2), 2).family != essp(2).family
