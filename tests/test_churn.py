"""Fleet churn as a traced axis: worker/pod death, rejoin, regime shifts.

Contract being pinned (the elastic-PS tentpole; see the churn sections of
``core/delays.py``, ``core/ps.py``, ``psrun/runtime.py`` and
``pods/elastic.py``):

- a **neutral** (all-live) `ChurnSchedule` is bit-identical to running
  with no schedule at all, for every model and on the wired path — churn
  is an overlay, not a fork of the engines;
- dead workers push nothing (their ``u_l2`` rows are exactly zero), their
  reader rows freeze, and the recorded ``Trace.live`` equals the schedule;
- the Trace-producer contract survives churn: seeded simulator and
  runtime traces stay bit-identical (BSP/SSP/ESSP, dense and compressed),
  VAP keeps exact decisions within the ulp budget — asserted through
  ``cross_validate`` / ``cross_validate_pods`` with the schedule applied
  to both engines;
- the staleness contract re-derives over the live set: for *any* generated
  schedule (hypothesis) live readers never violate the two-tier bound and
  never read past the barrier — the rejoin read is repaired by a forced
  burst before the worker computes;
- a pod dropped mid-run rejoins from a ``checkpoint.io`` snapshot **bit
  for bit** (`pods.elastic.run_with_pod_rejoin`): the spliced state equals
  the live state leaf-for-leaf and the three-segment trace equals the
  uninterrupted churned run;
- `TimeModel` charges churn faithfully: dead workers leave the
  slowest-worker max, and ``bw_scale`` scales the cross-pod wire floor;
- same-structure schedules reuse the compiled program (liveness arrays are
  jit arguments, like every other numeric knob).

Under the CI churn lane (``REPRO_FORCE_HOST_DEVICES=16``) the runtime
tests run genuinely sharded; on fewer devices they fall back to the widest
mesh available — the semantics are placement-independent.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import bsp, essp, simulate, simulate_jit, ssp, vap
from repro.core.consistency import ConsistencyConfig, compressed, podded
from repro.core.delays import churn_rates, make_churn, no_churn
from repro.core.timemodel import TimeModel
from repro.pods import (PodsRuntime, cross_validate_pods,
                        replica_divergence, run_with_pod_rejoin)
from repro.psrun import PSRuntime
from repro.psrun.runtime import default_mesh as flat_mesh_for
from repro.psrun.runtime import trace_count
from repro.psrun.validate import (TRACE_FIELDS, check_staleness_bound,
                                  cross_validate)
from test_pods import make_quad, pods_runtime_for

T = 18
OUTAGES = ((2, 4, 9), (5, 7, 14))        # (worker, down_from, up_at)


def assert_bit_identical(got, want, context=""):
    for name in TRACE_FIELDS:
        a, b = np.asarray(getattr(got, name)), np.asarray(getattr(want, name))
        np.testing.assert_array_equal(a, b, err_msg=f"{context}:{name}")


@pytest.fixture(scope="module")
def quad8():
    return make_quad(8)


@pytest.fixture(scope="module")
def flat8():
    return PSRuntime(flat_mesh_for(8))


@pytest.fixture(scope="module")
def pods8():
    return pods_runtime_for(8, 2)


def wired_cfg(s=2):
    return compressed(podded(essp(s), 2, s_xpod=3, t_net_xpod=6.0),
                      agg_clocks=2, topk_frac=0.5, quant="int8")


# ---------------------------------------------------------------------------
# simulator: churn is an overlay, not a fork
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("cfg", [
    bsp(), ssp(2), essp(2), ConsistencyConfig(model="async"),
    vap(0.5, staleness=4), wired_cfg(),
], ids=lambda c: f"{c.model}{'-wired' if c.comm_active else ''}")
def test_neutral_schedule_bit_identical(quad8, cfg):
    """An all-live schedule reproduces the schedule-free run bit for bit —
    every masking op collapses to identity when everyone is alive."""
    want = simulate_jit(quad8, cfg, T, seed=3)
    got = simulate_jit(quad8, cfg, T, seed=3, schedule=no_churn(T, 8))
    assert_bit_identical(got, want, context=cfg.model)


@pytest.mark.parametrize("cfg", [ssp(2), essp(2),
                                 ConsistencyConfig(model="async")],
                         ids=lambda c: c.model)
def test_dead_workers_push_nothing(quad8, cfg):
    sched = make_churn(T, 8, worker_outages=OUTAGES)
    tr = simulate_jit(quad8, cfg, T, seed=0, schedule=sched)
    live = np.asarray(tr.live)
    np.testing.assert_array_equal(live, np.asarray(sched.live))
    u = np.asarray(tr.u_l2)
    assert (u[~live] == 0.0).all()           # dead workers push nothing
    assert (u[live] > 0.0).any()             # survivors keep working
    assert np.isfinite(np.asarray(tr.loss_ref)).all()


def test_dead_reader_rows_freeze(quad8):
    """While a worker is down, its cview reader rows don't move: recorded
    staleness drifts by exactly -1 per clock (cview frozen, c advances)."""
    w, t0, t1 = 2, 4, 9
    sched = make_churn(T, 8, worker_outages=((w, t0, t1),))
    tr = simulate_jit(quad8, essp(2), T, seed=0, schedule=sched)
    stw = np.asarray(tr.staleness)[:, w, :]        # [T, P]
    # clock t0 records the frozen row (post-t0-1-delivery cview); from
    # there cview holds still while c advances
    for c in range(t0 + 1, t1):
        np.testing.assert_array_equal(stw[c], stw[t0] - (c - t0))
    # and the first read after rejoin is repaired back inside the bound
    chk = check_staleness_bound(tr, essp(2))
    assert chk["violations"] == 0, chk
    assert chk["max"] == -1, chk


def test_drop_vs_drain_inflight_policy(quad8):
    """The in-flight policy is observable: dropping a dead worker's queued
    updates changes the trajectory vs draining them, and both stay inside
    the re-derived staleness contract."""
    mk = lambda drop: make_churn(T, 8, worker_outages=((1, 3, 10),),
                                 drop_inflight=drop)
    tr_drain = simulate_jit(quad8, essp(2), T, seed=0, schedule=mk(False))
    tr_drop = simulate_jit(quad8, essp(2), T, seed=0, schedule=mk(True))
    assert not np.array_equal(np.asarray(tr_drain.loss_ref),
                              np.asarray(tr_drop.loss_ref))
    for tr in (tr_drain, tr_drop):
        assert check_staleness_bound(tr, essp(2))["violations"] == 0


def test_regime_shift_changes_delivery(quad8):
    """A mid-run straggler-regime shift thins deliveries for the slowed
    workers after the shift clock, and churn_rates exposes the vector."""
    cfg = essp(3).replace(push_prob=1.0)
    sched = make_churn(40, 8, regime_shift=(20, 3, 0.2))
    rates = np.asarray(churn_rates(cfg, sched, 8, jnp.asarray(25)))
    np.testing.assert_allclose(rates, [0.2] * 3 + [1.0] * 5)
    assert np.asarray(churn_rates(cfg, sched, 8, jnp.asarray(5))) is not None
    tr = simulate_jit(quad8, cfg, 40, seed=0, schedule=sched)
    d = np.asarray(tr.delivered).astype(float)     # [T, P(r), P(q)]
    # producer-side delivery frequency of the slowed workers drops
    before = d[:20, :, :3].mean()
    after = d[20:, :, :3].mean()
    assert after < before
    assert check_staleness_bound(tr, cfg)["violations"] == 0


# ---------------------------------------------------------------------------
# runtimes: the oracle contract survives churn
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("cfg", [
    bsp(), ssp(2), essp(2), ConsistencyConfig(model="async"),
    vap(0.5, staleness=4),
], ids=lambda c: c.model)
def test_runtime_bit_identical_under_worker_churn(quad8, flat8, cfg):
    sched = make_churn(T, 8, worker_outages=OUTAGES,
                       regime_shift=(10, 2, 0.3))
    out = cross_validate(quad8, cfg, T, runtime=flat8, seed=1,
                         schedule=sched)
    assert out["ok"], out


@pytest.mark.parametrize("cfg", [
    podded(ssp(2), 2, s_xpod=3, t_net_xpod=6.0),
    wired_cfg(),
    compressed(podded(ConsistencyConfig(model="async"), 2, t_net_xpod=6.0),
               agg_clocks=2, topk_frac=0.5, quant="int8"),
], ids=lambda c: f"{c.model}{'-wired' if c.comm_active else ''}")
def test_pods_runtime_bit_identical_under_pod_outage(quad8, pods8, cfg):
    """The acceptance contract: with churn enabled, seeded simulator and
    PodsRuntime traces are bit-identical on the compressed path too."""
    sched = make_churn(T, 8, n_pods=2, pod_outages=((1, 5, 12),),
                       bw_drop=(4, 10, 0.25))
    if isinstance(pods8, PodsRuntime):
        out = cross_validate_pods(quad8, cfg, T, runtime=pods8, seed=1,
                                  schedule=sched)
    else:  # single-device fallback: flat runtime, same contract
        out = cross_validate(quad8, cfg, T, runtime=pods8, seed=1,
                             schedule=sched)
    assert out["ok"], out


def test_runtime_resume_under_churn_bit_identical(quad8, flat8):
    """Segmented run_from under one absolute-clock schedule equals the
    uninterrupted churned run — schedules don't drift on resume."""
    cfg = essp(2)
    sched = make_churn(T, 8, worker_outages=OUTAGES)
    full = flat8.run(quad8, cfg, T, seed=2, schedule=sched)
    tr1, mid = flat8.run_from(quad8, cfg, 7,
                              flat8.init_state(quad8, cfg, seed=2),
                              schedule=sched)
    tr2, _ = flat8.run_from(quad8, cfg, T - 7, mid, schedule=sched)
    for name in TRACE_FIELDS:
        if name == "x_final":
            continue
        a = np.concatenate([np.asarray(getattr(tr1, name)),
                            np.asarray(getattr(tr2, name))])
        np.testing.assert_array_equal(a, np.asarray(getattr(full, name)),
                                      err_msg=name)


# ---------------------------------------------------------------------------
# property: any schedule keeps the live-set staleness contract (hypothesis)
# ---------------------------------------------------------------------------
@settings(max_examples=10, deadline=None)
@given(s=st.integers(min_value=0, max_value=4),
       s_xpod=st.integers(min_value=0, max_value=4),
       model=st.sampled_from(["ssp", "essp"]),
       n_pods=st.sampled_from([1, 2]),
       w=st.integers(min_value=0, max_value=7),
       t0=st.integers(min_value=1, max_value=10),
       dur=st.integers(min_value=1, max_value=10),
       drop=st.booleans(),
       seed=st.integers(min_value=0, max_value=99))
def test_any_schedule_keeps_live_staleness_bound(
        quad8, s, s_xpod, model, n_pods, w, t0, dur, drop, seed):
    """For any generated ChurnSchedule: live readers never violate the
    re-derived two-tier bound and never read past the barrier; dead
    workers push exactly nothing.  The fixed ring window keeps all draws
    inside one compile per (model, n_pods, policy)."""
    mk = ssp if model == "ssp" else essp
    cfg = podded(mk(s, window=10), n_pods, s_xpod=s_xpod, t_net_xpod=6.0)
    sched = make_churn(15, 8, n_pods=n_pods,
                       worker_outages=((w, t0, min(t0 + dur, 15)),),
                       drop_inflight=drop)
    tr = jax.jit(lambda sd, c, sc: simulate(quad8, c, 15, seed=sd,
                                            schedule=sc))(
        jnp.uint32(seed), cfg, sched)
    chk = check_staleness_bound(tr, cfg)
    assert chk["violations"] == 0, (model, n_pods, s, s_xpod, w, t0, chk)
    assert chk["max"] == -1
    live = np.asarray(tr.live)
    assert (np.asarray(tr.u_l2)[~live] == 0.0).all()
    if n_pods > 1:
        div = replica_divergence(tr, cfg)
        assert div["ok"], div


# ---------------------------------------------------------------------------
# elastic rejoin: checkpoint-restore + splice is bit-exact
# ---------------------------------------------------------------------------
@pytest.mark.parametrize(("cfg", "drop"), [
    (podded(essp(2), 2, s_xpod=3, t_net_xpod=6.0), False),
    (wired_cfg(), False),
    (wired_cfg(), True),
], ids=["dense-drain", "wired-drain", "wired-drop"])
def test_pod_rejoin_from_checkpoint_bit_exact(quad8, pods8, cfg, drop,
                                              tmp_path):
    """A pod dropped mid-run rejoins from its PSState checkpoint: the
    spliced state equals the continuous churned run's state leaf for leaf,
    the concatenated trace equals the uninterrupted run, and the first
    post-rejoin reads are already back inside the staleness bound."""
    res = run_with_pod_rejoin(pods8, quad8, cfg, T, pod=1, drop_clock=5,
                              rejoin_clock=12, seed=0,
                              ckpt_path=str(tmp_path / "pod1.npz"),
                              drop_inflight=drop)
    assert res["splice_exact"], res["splice_max_diff"]
    assert res["staleness_post"]["violations"] == 0
    full = pods8.run(quad8, cfg, T, seed=0, schedule=res["schedule"])
    for name in TRACE_FIELDS:
        if name == "x_final":
            continue
        np.testing.assert_array_equal(
            np.asarray(getattr(res["trace"], name)),
            np.asarray(getattr(full, name)), err_msg=name)


def test_rejoin_argument_guards(quad8, pods8):
    cfg = podded(essp(2), 2, s_xpod=3, t_net_xpod=6.0)
    with pytest.raises(ValueError, match="drop_clock"):
        run_with_pod_rejoin(pods8, quad8, cfg, T, pod=1, drop_clock=9,
                            rejoin_clock=4)


# ---------------------------------------------------------------------------
# TimeModel: churn is charged in seconds
# ---------------------------------------------------------------------------
def test_timemodel_dead_workers_leave_the_max(quad8):
    """The slowest-worker max is taken over the live set: killing the
    straggler shortens the clock, never lengthens it."""
    tm = TimeModel(seed=7)
    cfg = essp(2)
    tr_full = simulate_jit(quad8, cfg, T, seed=0)
    sched = make_churn(T, 8, worker_outages=((0, 2, 16), (5, 4, 12)))
    tr_churn = simulate_jit(quad8, cfg, T, seed=0, schedule=sched)
    # same fold -> same comp draws; masking can only reduce the per-clock
    # compute max (identical bit-for-bit on the all-live clocks)
    _, comp_f, _ = tm.per_clock(tr_full, "essp")
    _, comp_c, _ = tm.per_clock(tr_churn, "essp")
    comp_f, comp_c = np.asarray(comp_f), np.asarray(comp_c)
    dead_any = ~np.asarray(sched.live).all(axis=1)
    assert (comp_c <= comp_f + 1e-12).all()
    assert (comp_c[dead_any] < comp_f[dead_any]).any()


def test_timemodel_bw_scale_floors_the_wire(quad8):
    """bw_scale < 1 on the cross-pod tier raises the wire floor of exactly
    the crunch window's clocks; a neutral bw_scale changes nothing."""
    tm = TimeModel(t_comp=1e-6, straggler_sigma=0.0, rtt=0.0, seed=0)
    cfg = wired_cfg()
    tr = simulate_jit(quad8, cfg, T, seed=0)
    wall, _, _ = tm.per_clock(tr, cfg.model, cfg=cfg)
    neutral = make_churn(T, 8, n_pods=2, bw_drop=(0, T, 1.0))
    wall_n, _, _ = tm.per_clock(tr, cfg.model, cfg=cfg, schedule=neutral)
    np.testing.assert_array_equal(np.asarray(wall), np.asarray(wall_n))
    crunch = make_churn(T, 8, n_pods=2, bw_drop=(4, 10, 0.25))
    wall_c, _, _ = tm.per_clock(tr, cfg.model, cfg=cfg, schedule=crunch)
    wall, wall_c = np.asarray(wall), np.asarray(wall_c)
    shipped = np.asarray(tr.ship_floats).sum(axis=1) > 0
    window = np.zeros(T, bool)
    window[4:10] = True
    assert (wall_c[window & shipped] > wall[window & shipped]).all()
    np.testing.assert_array_equal(wall_c[~window], wall[~window])


# ---------------------------------------------------------------------------
# compile reuse + structure guards
# ---------------------------------------------------------------------------
def test_same_shape_schedules_reuse_compile(quad8, flat8):
    cfg = essp(2)
    s1 = make_churn(T, 8, worker_outages=((1, 3, 9),))
    flat8.run(quad8, cfg, T, seed=0, schedule=s1)          # warm
    n0 = trace_count()
    s2 = make_churn(T, 8, worker_outages=((4, 2, 15), (6, 6, 8)))
    tr = flat8.run(quad8, cfg, T, seed=1, schedule=s2)
    assert np.isfinite(np.asarray(tr.loss_ref)).all()
    assert trace_count() == n0          # liveness arrays are jit arguments


def test_churn_structure_guards(quad8, flat8):
    cfg = essp(2)
    with pytest.raises(ValueError, match="workers"):
        flat8.run(quad8, cfg, T, schedule=no_churn(T, 4))
    fn = flat8.run_fn(quad8, cfg, T)    # compiled churn-free
    with pytest.raises(ValueError, match="churn"):
        fn(0, cfg, no_churn(T, 8))
